"""Thread-reachability engine for the concurrency rule pack (R101–R105).

The serving stack (PR 8) splits work between the asyncio event loop and a
dedicated engine worker thread; this module classifies every function in a
module by which thread(s) can execute it, the way :mod:`jitscope` classifies
code by traced-ness.

Model
-----
* **Roots.**  Every ``async def`` body runs on the event loop.  A function
  passed as ``threading.Thread(target=...)`` is a worker-thread root; each
  distinct ``Thread(target=...)`` site is its own thread identity, so two
  different threads driving one object are distinguishable.  Functions
  handed to ``loop.run_in_executor(...)`` / ``asyncio.to_thread(...)`` are
  worker roots too (identity ``executor:<name>``) and are remembered as
  *executor targets* — blocking there is the whole point.  Functions
  scheduled back onto the loop via ``call_soon_threadsafe`` (and plain
  ``call_soon`` / ``call_later``) run on the loop; ``call_soon_threadsafe``
  targets are additionally remembered as the sanctioned cross-thread
  channel (R102 exempts reads inside them).
* **Propagation.**  Identities flow along same-module call edges: bare
  names to module/local functions, ``self.m()`` to the enclosing class,
  receivers whose class is inferable from ``x = ClassName(...)``
  assignments, and — over-approximation — method names unique to a single
  module class.  Worker identities never flow *into* an ``async def``
  (a worker can only schedule a coroutine back onto the loop, it cannot
  run its body inline).
* **Receiver typing.**  Locals and ``self.*`` attributes are typed by the
  constructor they were assigned from (``queue.Queue`` → ``"queue"``,
  ``asyncio.Queue`` → ``"aqueue"``, ``threading.Lock`` → ``"lock"``,
  ``Engine(...)`` → ``"engine"``, ...) plus name heuristics for parameters
  (``loop`` / ``engine`` / ``eng``).  This is what lets R101 distinguish a
  blocking ``queue.Queue.get`` from an awaited ``asyncio.Queue.get``.

Known approximations (see README "Static analysis & sanitizers")
----------------------------------------------------------------
* Per-module only: a call into another module is invisible, so a blocking
  helper imported from elsewhere is not flagged (under-approximation).
* A sync helper reachable from both the loop and a worker carries both
  identities and is checked under both rule sets (over-approximation: one
  of the call sites may be dead).
* Receiver typing is assignment-based; a value that changes type, flows
  through a container, or arrives via an untyped parameter is unknown and
  its method calls are not checked (under-approximation).
* The unique-method-name fallback can mis-resolve a call on an unknown
  receiver to the one class that happens to define that name
  (over-approximation, rarely wrong in practice for private ``_names``).
"""

from __future__ import annotations

import ast
import dataclasses
from collections import deque
from typing import Dict, Iterator, List, Optional, Set

from tools.tracelint.jitscope import build_alias_map, dotted_name

LOOP_IDENTITY = "event-loop"

#: constructor dotted path -> receiver kind
KIND_CTORS = {
    "queue.Queue": "queue",
    "queue.LifoQueue": "queue",
    "queue.PriorityQueue": "queue",
    "queue.SimpleQueue": "simplequeue",
    "threading.Thread": "thread",
    "threading.Lock": "lock",
    "threading.RLock": "lock",
    "threading.Semaphore": "lock",
    "threading.BoundedSemaphore": "lock",
    "threading.Condition": "condition",
    "threading.Event": "tevent",
    "asyncio.Queue": "aqueue",
    "asyncio.LifoQueue": "aqueue",
    "asyncio.PriorityQueue": "aqueue",
    "asyncio.Event": "aevent",
    "asyncio.Lock": "alock",
    "asyncio.Condition": "alock",
    "asyncio.Semaphore": "alock",
    "asyncio.Future": "afuture",
    "concurrent.futures.ThreadPoolExecutor": "executor",
    "concurrent.futures.ProcessPoolExecutor": "executor",
    "concurrent.futures.Future": "cfuture",
}

#: parameter-name heuristics (no annotation needed)
PARAM_KINDS = {"loop": "loop", "engine": "engine", "eng": "engine"}

LOOP_FACTORIES = {
    "asyncio.get_event_loop",
    "asyncio.get_running_loop",
    "asyncio.new_event_loop",
}

#: annotation dotted path -> kind (constructors double as annotations)
ANN_KINDS = dict(KIND_CTORS)
ANN_KINDS["asyncio.AbstractEventLoop"] = "loop"

#: kinds that ARE cross-thread channels/primitives — reading the attribute
#: that holds one is not data sharing (R102 skips them)
CHANNEL_KINDS = frozenset(
    {
        "queue",
        "simplequeue",
        "aqueue",
        "aevent",
        "alock",
        "lock",
        "condition",
        "tevent",
        "loop",
        "afuture",
        "cfuture",
        "thread",
        "executor",
    }
)


def walk_body(fn: ast.AST) -> Iterator[ast.AST]:
    """All nodes in a function body, excluding nested function definitions
    (those are classified as their own functions)."""
    stack = list(fn.body if isinstance(fn.body, list) else [fn.body])
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue
            stack.append(child)


@dataclasses.dataclass
class FuncInfo:
    qual: str
    name: str
    node: ast.AST
    cls: Optional[str]  # enclosing class name, if a method
    parent: Optional[str]  # enclosing function qualname, if nested
    is_async: bool


class ThreadIndex:
    """Per-module index: thread identities reaching each function, plus the
    receiver-kind environment rules use to type method calls."""

    def __init__(self, tree: ast.Module, aliases: Optional[Dict[str, str]] = None):
        self.tree = tree
        self.aliases = aliases if aliases is not None else build_alias_map(tree)
        self.funcs: Dict[str, FuncInfo] = {}
        self._by_node: Dict[int, str] = {}
        self._module_fns: Dict[str, str] = {}
        self._classes: Set[str] = set()
        self._methods: Dict[str, Dict[str, str]] = {}
        self._method_name_index: Dict[str, List[str]] = {}
        self._enclosing: Dict[int, Optional[str]] = {}
        # qualname -> set of thread identities that can execute it
        self.identities: Dict[str, Set[str]] = {}
        # qualname -> human-readable provenance (for messages)
        self.provenance: Dict[str, str] = {}
        # sanctioned loop-handoff targets (call_soon_threadsafe)
        self.threadsafe_targets: Set[str] = set()
        # functions whose *purpose* is to block off-loop (run_in_executor)
        self.executor_targets: Set[str] = set()
        self.edges: Dict[str, Set[str]] = {}
        # class -> attr -> kind / instance class
        self.self_kinds: Dict[str, Dict[str, str]] = {}
        self.self_insts: Dict[str, Dict[str, str]] = {}
        self._fn_kinds: Dict[str, Dict[str, str]] = {}
        self._fn_insts: Dict[str, Dict[str, str]] = {}

        self._collect_funcs()
        self._map_enclosing()
        self._infer_kinds()
        self._collect_roots_and_edges()
        self._propagate()

    # -- discovery ----------------------------------------------------------

    def _collect_funcs(self) -> None:
        def visit(node: ast.AST, prefix: str, cls: Optional[str], parent: Optional[str]):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.ClassDef):
                    self._classes.add(child.name)
                    self._methods.setdefault(child.name, {})
                    visit(child, child.name + ".", child.name, parent)
                elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = prefix + child.name
                    self.funcs[qual] = FuncInfo(
                        qual,
                        child.name,
                        child,
                        cls,
                        parent,
                        isinstance(child, ast.AsyncFunctionDef),
                    )
                    self._by_node[id(child)] = qual
                    if parent is None and cls is not None:
                        self._methods[cls][child.name] = qual
                    elif parent is None and cls is None:
                        self._module_fns.setdefault(child.name, qual)
                    visit(child, qual + ".", cls, qual)
                else:
                    visit(child, prefix, cls, parent)

        visit(self.tree, "", None, None)
        for methods in self._methods.values():
            for name, qual in methods.items():
                self._method_name_index.setdefault(name, []).append(qual)

    def _map_enclosing(self) -> None:
        def visit(node: ast.AST, fq: Optional[str]):
            fq = self._by_node.get(id(node), fq)
            for child in ast.iter_child_nodes(node):
                self._enclosing[id(child)] = fq
                visit(child, fq)

        visit(self.tree, None)

    def enclosing(self, node: ast.AST) -> Optional[str]:
        return self._enclosing.get(id(node))

    # -- receiver-kind inference --------------------------------------------

    def _param_env(self, info: FuncInfo):
        kinds: Dict[str, str] = {}
        insts: Dict[str, str] = {}
        a = info.node.args
        for p in a.posonlyargs + a.args + a.kwonlyargs:
            if p.arg in ("self", "cls"):
                continue
            k = PARAM_KINDS.get(p.arg)
            if p.annotation is not None:
                d = dotted_name(p.annotation, self.aliases)
                if d in ANN_KINDS:
                    k = ANN_KINDS[d]
                elif d in self._classes:
                    insts[p.arg] = d
            if k is not None:
                kinds[p.arg] = k
        return kinds, insts

    def _expr_kind_env(self, expr, kinds, cls) -> Optional[str]:
        if isinstance(expr, ast.Name):
            return kinds.get(expr.id)
        if (
            isinstance(expr, ast.Attribute)
            and isinstance(expr.value, ast.Name)
            and expr.value.id in ("self", "cls")
            and cls is not None
        ):
            return self.self_kinds.get(cls, {}).get(expr.attr)
        return None

    def _expr_inst_env(self, expr, insts, cls) -> Optional[str]:
        if isinstance(expr, ast.Await):
            return self._expr_inst_env(expr.value, insts, cls)
        if isinstance(expr, ast.Call):
            d = dotted_name(expr.func, self.aliases)
            if d in self._classes:
                return d
            return None
        if isinstance(expr, ast.Name):
            return insts.get(expr.id)
        if (
            isinstance(expr, ast.Attribute)
            and isinstance(expr.value, ast.Name)
            and expr.value.id in ("self", "cls")
            and cls is not None
        ):
            return self.self_insts.get(cls, {}).get(expr.attr)
        return None

    def _value_kind(self, expr, kinds, cls) -> Optional[str]:
        if isinstance(expr, ast.Await):
            return self._value_kind(expr.value, kinds, cls)
        if isinstance(expr, ast.Call):
            d = dotted_name(expr.func, self.aliases)
            if d in KIND_CTORS:
                return KIND_CTORS[d]
            if d in LOOP_FACTORIES:
                return "loop"
            if d == "asyncio.run_coroutine_threadsafe":
                return "cfuture"
            if d is not None and d.split(".")[-1] == "Engine":
                return "engine"
            if isinstance(expr.func, ast.Attribute):
                rk = self._expr_kind_env(expr.func.value, kinds, cls)
                if rk == "loop" and expr.func.attr == "create_future":
                    return "afuture"
                if rk == "executor" and expr.func.attr == "submit":
                    return "cfuture"
            return None
        return self._expr_kind_env(expr, kinds, cls)

    def _infer_kinds(self) -> None:
        # three global passes: pass 1 seeds self.* tables from __init__-style
        # assignments, later passes see the completed tables (covers locals
        # aliased from self attrs and cross-method assignment order)
        for _ in range(3):
            for qual, info in self.funcs.items():
                kinds, insts = self._param_env(info)
                kinds.update(self._fn_kinds.get(qual, {}))
                insts.update(self._fn_insts.get(qual, {}))
                for _ in range(2):  # local fixpoint over out-of-order reads
                    for node in walk_body(info.node):
                        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                            continue
                        tgt = node.targets[0]
                        k = self._value_kind(node.value, kinds, info.cls)
                        c = self._expr_inst_env(node.value, insts, info.cls)
                        if isinstance(tgt, ast.Name):
                            if k is not None:
                                kinds[tgt.id] = k
                            if c is not None:
                                insts[tgt.id] = c
                        elif (
                            isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"
                            and info.cls is not None
                        ):
                            if k is not None:
                                self.self_kinds.setdefault(info.cls, {})[tgt.attr] = k
                            if c is not None:
                                self.self_insts.setdefault(info.cls, {})[tgt.attr] = c
                self._fn_kinds[qual] = kinds
                self._fn_insts[qual] = insts

    def receiver_kind(self, qual: Optional[str], expr: ast.AST) -> Optional[str]:
        """Kind of ``expr`` used as a method receiver inside function ``qual``."""
        info = self.funcs.get(qual) if qual else None
        kinds = self._fn_kinds.get(qual, {}) if qual else {}
        return self._expr_kind_env(expr, kinds, info.cls if info else None)

    def receiver_inst(self, qual: Optional[str], expr: ast.AST) -> Optional[str]:
        info = self.funcs.get(qual) if qual else None
        insts = self._fn_insts.get(qual, {}) if qual else {}
        return self._expr_inst_env(expr, insts, info.cls if info else None)

    # -- reference & call resolution ----------------------------------------

    def resolve_ref(self, expr: ast.AST, from_qual: Optional[str]) -> Optional[str]:
        """Resolve a function *reference* (Thread target, call_soon arg,
        callee expression) to a qualname, or None."""
        if isinstance(expr, ast.Name):
            q = from_qual
            while q is not None:
                cand = self.funcs.get(f"{q}.{expr.id}")
                if cand is not None:
                    return cand.qual
                q = self.funcs[q].parent if q in self.funcs else None
            return self._module_fns.get(expr.id)
        if isinstance(expr, ast.Call):
            d = dotted_name(expr.func, self.aliases)
            if d == "functools.partial" and expr.args:
                return self.resolve_ref(expr.args[0], from_qual)
            return None
        if isinstance(expr, ast.Attribute):
            recv = expr.value
            info = self.funcs.get(from_qual) if from_qual else None
            cls = info.cls if info else None
            if isinstance(recv, ast.Name) and recv.id in ("self", "cls") and cls:
                return self._methods.get(cls, {}).get(expr.attr)
            c = self.receiver_inst(from_qual, recv)
            if c is not None:
                return self._methods.get(c, {}).get(expr.attr)
            if self.receiver_kind(from_qual, recv) is None:
                # unique-method-name fallback (documented over-approximation)
                quals = self._method_name_index.get(expr.attr, [])
                if len(quals) == 1 and expr.attr not in self._module_fns:
                    return quals[0]
        return None

    def _resolve_call_target(self, call: ast.Call, fq: Optional[str]) -> Optional[str]:
        f = call.func
        if isinstance(f, ast.Name):
            return self.resolve_ref(f, fq)
        if isinstance(f, ast.Attribute):
            # method calls on typed primitives (queues, locks, the engine)
            # are leaf operations, not call-graph edges
            if self.receiver_kind(fq, f.value) is not None:
                return None
            return self.resolve_ref(f, fq)
        return None

    # -- roots, edges, propagation ------------------------------------------

    def _collect_roots_and_edges(self) -> None:
        self._loop_scheduled: Set[str] = set()
        self._worker_roots: Dict[str, str] = {}  # qual -> identity
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            fq = self._enclosing.get(id(node))
            d = dotted_name(node.func, self.aliases)
            if d is not None and KIND_CTORS.get(d) == "thread":
                target = next(
                    (kw.value for kw in node.keywords if kw.arg == "target"), None
                )
                ref = self.resolve_ref(target, fq) if target is not None else None
                if ref is not None:
                    self._worker_roots[ref] = f"thread:{self.funcs[ref].name}"
                    self.provenance.setdefault(
                        ref, f"threading.Thread target '{self.funcs[ref].name}'"
                    )
            elif d == "asyncio.to_thread" and node.args:
                ref = self.resolve_ref(node.args[0], fq)
                if ref is not None:
                    self._worker_roots[ref] = f"executor:{self.funcs[ref].name}"
                    self.executor_targets.add(ref)
                    self.provenance.setdefault(ref, "asyncio.to_thread target")
            elif isinstance(node.func, ast.Attribute):
                attr = node.func.attr
                if attr == "run_in_executor" and len(node.args) >= 2:
                    ref = self.resolve_ref(node.args[1], fq)
                    if ref is not None:
                        self._worker_roots[ref] = f"executor:{self.funcs[ref].name}"
                        self.executor_targets.add(ref)
                        self.provenance.setdefault(ref, "run_in_executor target")
                elif attr in ("call_soon_threadsafe", "call_soon") and node.args:
                    ref = self.resolve_ref(node.args[0], fq)
                    if ref is not None:
                        self._loop_scheduled.add(ref)
                        if attr == "call_soon_threadsafe":
                            self.threadsafe_targets.add(ref)
                        self.provenance.setdefault(ref, f"scheduled via {attr}")
                elif attr == "call_later" and len(node.args) >= 2:
                    ref = self.resolve_ref(node.args[1], fq)
                    if ref is not None:
                        self._loop_scheduled.add(ref)
                        self.provenance.setdefault(ref, "scheduled via call_later")
            if fq is not None:
                callee = self._resolve_call_target(node, fq)
                if callee is not None and callee != fq:
                    self.edges.setdefault(fq, set()).add(callee)

    def _mark(self, qual: str, ident: str, why: str) -> bool:
        s = self.identities.setdefault(qual, set())
        if ident in s:
            return False
        s.add(ident)
        self.provenance.setdefault(qual, why)
        return True

    def _bfs(self, roots: List[str], ident: str, *, skip_async: bool) -> None:
        dq = deque(roots)
        while dq:
            q = dq.popleft()
            for callee in self.edges.get(q, ()):
                info = self.funcs[callee]
                if skip_async and info.is_async:
                    continue
                if self._mark(callee, ident, f"called from '{q}'"):
                    dq.append(callee)

    def _propagate(self) -> None:
        loop_roots = []
        for qual, info in self.funcs.items():
            if info.is_async:
                self._mark(qual, LOOP_IDENTITY, f"async def '{info.name}'")
                loop_roots.append(qual)
        for qual in self._loop_scheduled:
            self._mark(qual, LOOP_IDENTITY, self.provenance.get(qual, "scheduled onto the loop"))
            loop_roots.append(qual)
        self._bfs(loop_roots, LOOP_IDENTITY, skip_async=False)
        for qual, ident in self._worker_roots.items():
            self._mark(qual, ident, self.provenance.get(qual, "worker root"))
            self._bfs([qual], ident, skip_async=True)

    # -- public predicates ---------------------------------------------------

    @property
    def has_roots(self) -> bool:
        return bool(self.identities)

    def loop_side(self, qual: str) -> bool:
        return LOOP_IDENTITY in self.identities.get(qual, ())

    def worker_side(self, qual: str) -> bool:
        return any(i != LOOP_IDENTITY for i in self.identities.get(qual, ()))

    def roots_of(self, qual: str) -> Set[str]:
        return self.identities.get(qual, set())

    def why(self, qual: str) -> str:
        return self.provenance.get(qual, "reachable")
