"""Text and JSON reporters for tracelint findings."""

from __future__ import annotations

import json
from typing import Dict, List, Sequence

from tools.tracelint.core import BaselineEntry, Finding


def text_report(
    new: Sequence[Finding],
    baselined: Sequence[Finding],
    stale: Sequence[BaselineEntry],
    n_files: int,
) -> str:
    lines: List[str] = []
    for f in new:
        sym = f" [in {f.symbol}]" if f.symbol else ""
        lines.append(f"{f.path}:{f.line}:{f.col + 1} {f.rule} {f.message}{sym}")
        if f.snippet:
            lines.append(f"    {f.snippet}")
    if new:
        lines.append("")
    counts: Dict[str, int] = {}
    for f in new:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    by_rule = ", ".join(f"{r}={n}" for r, n in sorted(counts.items())) or "none"
    lines.append(
        f"tracelint: {len(new)} new finding(s) ({by_rule}) in {n_files} file(s); "
        f"{len(baselined)} baselined"
    )
    if stale:
        lines.append(
            f"tracelint: {len(stale)} stale baseline entr(y/ies) no longer match "
            f"any finding — prune them:"
        )
        for e in stale:
            lines.append(f"    {e.path}: {e.rule} {e.snippet!r}")
    return "\n".join(lines)


def json_report(
    new: Sequence[Finding],
    baselined: Sequence[Finding],
    stale: Sequence[BaselineEntry],
    n_files: int,
) -> str:
    return json.dumps(
        {
            "version": 1,
            "files_checked": n_files,
            "new_findings": [f.to_json() for f in new],
            "baselined_findings": [f.to_json() for f in baselined],
            "stale_baseline": [
                {"rule": e.rule, "path": e.path, "snippet": e.snippet} for e in stale
            ],
        },
        indent=2,
    )
