"""Quickstart: the whole thought-calibration loop in one script.

    PYTHONPATH=src python examples/quickstart.py [--steps 150]

1. trains a small reasoning LM on synthetic graph-grounded traces,
2. fits a PCA + linear consistency probe on its hidden states,
3. calibrates the stopping threshold λ with Learn-then-Test (δ=0.1, ε=0.1),
4. serves test prompts through the batched engine with the calibrated
   early-exit controller, and compares against Crop and full-budget runs.
"""

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_reduced
from repro.core import controller as C
from repro.core import (calibrate_stopping_rule, fit_pca, pad_components,
                        probe_scores, smooth_scores, train_probe, transform)
from repro.core.risks import risk_inconsistency
from repro.core.segmentation import segment_mean_pool, segment_steps
from repro.data import DataConfig, PackedDataset, TraceConfig, generate_dataset
from repro.data.traces import BOUNDARY_IDS, MARKER_IDS
from repro.models import model as M
from repro.serving import Engine, EngineConfig, ServeRequest
from repro.training.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    # 1. train a small reasoning LM ----------------------------------------
    cfg = get_reduced("qwen3-8b").replace(vocab_size=512, probe_dim=32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ds = PackedDataset(DataConfig(seq_len=256, batch_size=16, num_traces=2000))
    print(f"== training {cfg.arch_id} (reduced) for {args.steps} steps ==")
    params, _, _ = train(cfg, params, ds.batches(), steps=args.steps,
                         peak_lr=1e-3, moe_impl="dense", log_every=50)

    # 2. probe hidden states -------------------------------------------------
    print("== fitting consistency probe ==")
    traces = generate_dataset(300, TraceConfig(), seed=123)
    fwd = jax.jit(lambda p, t: M.forward(cfg, p, t, compute_dtype="float32",
                                         moe_impl="dense").hidden)
    reps_all, labels_all, per_trace = [], [], []
    for tr in traces:
        toks = jnp.asarray(tr.tokens[None])
        hidden = fwd(params, toks)
        seg = segment_steps(toks, BOUNDARY_IDS, MARKER_IDS)
        reps, _ = segment_mean_pool(hidden, seg.step_id, tr.labels.num_steps)
        reps = np.asarray(reps[0])
        per_trace.append(reps)
        reps_all.append(reps)
        labels_all.append(tr.labels.consistent_at.astype(np.float32))
    x = np.concatenate(reps_all)
    y = np.concatenate(labels_all)
    pca = pad_components(fit_pca(jnp.asarray(x), 32), 32)
    probe = train_probe(jax.random.PRNGKey(1), "linear",
                        np.asarray(transform(pca, jnp.asarray(x))), y)
    print(f"probe val AUROC = {probe.val_auroc:.3f}")

    # 3. LTT calibration -----------------------------------------------------
    cal_scores = [smooth_scores(probe_scores(
        probe, np.asarray(transform(pca, jnp.asarray(r)))), 10)
        for r in per_trace[:200]]

    def risk(i, t):
        return risk_inconsistency(traces[i].labels, t)

    res = calibrate_stopping_rule(cal_scores, risk, delta=0.1, epsilon=0.1)
    print(f"calibrated λ = {res.lam} (δ=0.1, ε=0.1, n={res.n})")

    # 4. serve with early exit ------------------------------------------------
    pp = C.init_probe_params(cfg.d_model, 32)._replace(
        pca_mean=pca.mean, pca_comps=pca.components,
        w1=jnp.asarray(probe.params["w"]), b1=jnp.asarray(probe.params["b"]),
        lam=jnp.asarray(res.lam if res.lam is not None else jnp.inf, jnp.float32))
    ctrl = C.ControllerConfig(BOUNDARY_IDS, MARKER_IDS, window=10,
                              min_steps=2, probe_dim=32)
    test = generate_dataset(args.requests, TraceConfig(), seed=999)
    reqs = [ServeRequest(uid=i, prompt=t.tokens[:6].astype(np.int32), max_new=220)
            for i, t in enumerate(test)]
    for policy, kw in (("calibrated", {}), ("crop", {"crop_budget": 48}),
                       ("full", {})):
        eng = Engine(cfg, params, ctrl=ctrl, probe_params=pp,
                     engine=EngineConfig(lanes=8, policy=policy, **kw))
        rs = eng.run(reqs)
        think = np.mean([r.think_tokens for r in rs])
        early = np.mean([r.exited_early for r in rs])
        acc = np.mean([r.answer == test[i].true_answer
                       for i, r in enumerate(rs)])
        # NOTE: in generative serving the model *continues* from a short
        # prompt, so the world's hidden answer is not inferable — acc here
        # is ~chance by construction. The paper's accuracy protocol
        # (truncate a given trajectory, force the answer) is what
        # benchmarks/bench_fig2_indist.py measures.
        print(f"policy={policy:10s} mean_think_tokens={think:6.1f} "
              f"early_exit={early:.2f} (answer-match vs hidden world: {acc:.2f})")


if __name__ == "__main__":
    main()
