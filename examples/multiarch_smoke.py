"""Run one forward + one train step + one decode step for EVERY assigned
architecture (reduced configs) — the 10-arch coverage demo.

    PYTHONPATH=src python examples/multiarch_smoke.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_reduced
from repro.models import model as M
from repro.training import adamw_init, make_train_step
from repro.training.schedules import get_schedule


def main():
    key = jax.random.PRNGKey(0)
    sched = get_schedule("cosine", peak_lr=1e-3, warmup=1, total=10)
    for arch in ARCH_IDS:
        t0 = time.time()
        cfg = get_reduced(arch)
        params = M.init_params(cfg, key)
        B, S = 2, 128
        shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
        tokens = jax.random.randint(key, shape, 0, cfg.vocab_size)
        ctx = None
        if cfg.uses_cross_attn:
            ca = cfg.cross_attn
            ctx = jax.random.normal(key, (B, ca.num_context_tokens, ca.context_dim))

        step = jax.jit(make_train_step(cfg, sched, moe_impl="dense"))
        opt = adamw_init(params)
        labels = jnp.roll(tokens, -1, 1)
        if ctx is not None:
            params2, _, m = step(params, opt, tokens, labels, ctx)
        else:
            params2, _, m = step(params, opt, tokens, labels)

        _, _, cache = M.prefill(cfg, params, tokens[:, :64], ctx, cache_len=80,
                                compute_dtype="float32", moe_impl="dense")
        win = cfg.sliding_window if cfg.native_swa else 0
        lg, hid, cache = M.decode_step(cfg, params, cache, tokens[:, 64:65],
                                       window=win, compute_dtype="float32",
                                       moe_impl="dense")
        print(f"{arch:25s} [{cfg.family:6s}] loss={float(m['loss']):.3f} "
              f"decode_logits={tuple(lg.shape)} ({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
