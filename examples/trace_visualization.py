"""Figure-5 reproduction: per-step consistency-probe scores over one trace.

    PYTHONPATH=src python examples/trace_visualization.py

Prints each reasoning step with its probe score as a text heat bar — the
score should dip on backtracks and rise once the model re-confirms the final
answer, as in the paper's qualitative example.
Relies on benchmark artifacts (run ``python -m benchmarks.run --only fig2``
first, or it will build the pipeline from scratch).
"""

import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np

from benchmarks import common


def bar(p: float, width: int = 30) -> str:
    n = int(p * width)
    return "#" * n + "." * (width - n)


def main():
    pipe = common.build_pipeline()
    scores = common.variant_scores(pipe, "test", "consistent")
    feats = pipe.feats["test"]
    # pick a solvable trace with a long overthink tail
    pick = max(
        range(len(feats)),
        key=lambda i: (feats[i].trace.solvable, feats[i].n_steps))
    f, s = feats[pick], scores[pick]
    tr = f.trace
    kinds = []
    # recover step kinds from labels for display
    for t in range(f.n_steps):
        if tr.labels.is_leaf[t] and tr.labels.is_novel[t]:
            kinds.append("ANSWER ")
        elif tr.labels.is_leaf[t]:
            kinds.append("reattempt")
        elif tr.labels.is_novel[t]:
            kinds.append("progress")
        else:
            kinds.append("backtrack")
    print(f"trace: solvable={tr.solvable} true_answer={tr.true_answer} "
          f"final={tr.final_answer} steps={f.n_steps}")
    print(f"{'step':>4} {'kind':>10} {'P(consistent)':>14}  ")
    for t in range(f.n_steps):
        mark = " <- first correct" if (tr.labels.correct_at[t]
                                       and not tr.labels.correct_at[:t].any()) else ""
        print(f"{t:4d} {kinds[t]:>10} {s[t]:14.3f}  |{bar(s[t])}|{mark}")


if __name__ == "__main__":
    main()
